"""Roofline analysis (brief deliverable g).

Reads the dry-run JSONL (loop-aware per-device HLO costs) and derives, per
(arch x shape) on the single-pod mesh:

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s        [s]
    memory term     = HLO_bytes_per_device / HBM_bw             [s]
    collective term = collective_bytes_per_device / ICI_bw      [s]

(The per-device numbers already divide by the chip count — the global
HLO_FLOPs / (chips x peak) of the brief.)  Also reports MODEL_FLOPS = 6·N·D
(train; 2·N·D prefill/decode; N = active params for MoE) and the usefulness
ratio MODEL_FLOPS / HLO_FLOPs that exposes remat/redundant compute.
"""
from __future__ import annotations

import json
import sys

from repro.launch.mesh import TPU_V5E

PEAK = TPU_V5E["peak_flops_bf16"]
HBM = TPU_V5E["hbm_bw"]
ICI = TPU_V5E["ici_bw"]


def load(path: str = "dryrun_results.jsonl") -> list[dict]:
    out = {}
    for line in open(path):
        line = line.strip()
        if not line:
            continue
        r = json.loads(line)
        key = (r["arch"], r["shape"], r.get("mesh", "-"))
        out[key] = r  # last record wins (reruns supersede)
    return list(out.values())


def roofline_row(r: dict) -> dict:
    n_dev = r["num_devices"]
    t_comp = r["flops_per_device"] / PEAK
    t_mem = r["bytes_per_device"] / HBM
    t_coll = r["collective_bytes_per_device"]["_total"] / ICI
    dominant = max(
        (("compute", t_comp), ("memory", t_mem), ("collective", t_coll)),
        key=lambda kv: kv[1],
    )[0]
    mult = 6.0 if r["kind"] == "train" else 2.0
    model_flops = mult * r["active_param_count"] * r["tokens"]
    hlo_global = r["flops_per_device"] * n_dev
    return {
        "arch": r["arch"],
        "shape": r["shape"],
        "mesh": r.get("mesh", "-"),
        "strategy": r.get("strategy", "-"),
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "bottleneck": dominant,
        "model_flops": model_flops,
        "hlo_flops_global": hlo_global,
        "useful_ratio": model_flops / hlo_global if hlo_global else float("nan"),
        "step_lower_bound_s": max(t_comp, t_mem, t_coll),
        "mem_gb_per_dev": (r["memory"]["argument_bytes"]
                           + r["memory"]["temp_bytes"]) / n_dev / 2**30,
    }


def run(path: str = "dryrun_results.jsonl", mesh: str = "16x16", out=print):
    rows = []
    out("\n== Roofline (single-pod 16x16, per-device terms) ==")
    hdr = ["arch", "shape", "compute_s", "memory_s", "collective_s",
           "bottleneck", "useful", "mem/dev GB"]
    out("  ".join(h.ljust(14) for h in hdr))
    for r in sorted(load(path), key=lambda r: (r["arch"], r["shape"])):
        if r["status"] == "SKIP":
            if mesh == "16x16":
                out(f"{r['arch']:14.14s}  {r['shape']:14.14s}  SKIP ({r['reason'][:70]})")
            continue
        if r["status"] != "OK" or r.get("mesh") != mesh:
            continue
        row = roofline_row(r)
        rows.append(row)
        out("  ".join([
            row["arch"][:14].ljust(14), row["shape"][:14].ljust(14),
            f"{row['t_compute_s']:.3e}".ljust(14), f"{row['t_memory_s']:.3e}".ljust(14),
            f"{row['t_collective_s']:.3e}".ljust(14), row["bottleneck"].ljust(14),
            f"{row['useful_ratio']:.3f}".ljust(14), f"{row['mem_gb_per_dev']:.2f}",
        ]))
    return rows


if __name__ == "__main__":
    run(sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.jsonl")


def markdown_table(path: str = "dryrun_results.jsonl", mesh: str = "16x16") -> str:
    """§Roofline markdown for EXPERIMENTS.md."""
    lines = [
        "| arch | shape | compute s | memory s | collective s | bottleneck "
        "| MODEL/HLO | mem GB/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(load(path), key=lambda r: (r["arch"], r["shape"])):
        if r["status"] == "SKIP":
            if mesh == "16x16":
                lines.append(
                    f"| {r['arch']} | {r['shape']} | — | — | — | SKIP | — | — |"
                )
            continue
        if r["status"] != "OK" or r.get("mesh") != mesh:
            continue
        w = roofline_row(r)
        lines.append(
            f"| {w['arch']} | {w['shape']} | {w['t_compute_s']:.2e} | "
            f"{w['t_memory_s']:.2e} | {w['t_collective_s']:.2e} | "
            f"{w['bottleneck']} | {w['useful_ratio']:.3f} | "
            f"{w['mem_gb_per_dev']:.2f} |"
        )
    return "\n".join(lines)


def compare_markdown(base_path: str, opt_path: str, mesh: str = "16x16") -> str:
    """Baseline vs optimized step-lower-bound comparison (§Perf summary)."""
    base = {(r["arch"], r["shape"]): roofline_row(r) for r in load(base_path)
            if r["status"] == "OK" and r.get("mesh") == mesh}
    opt = {(r["arch"], r["shape"]): roofline_row(r) for r in load(opt_path)
           if r["status"] == "OK" and r.get("mesh") == mesh}
    lines = [
        "| arch | shape | baseline bound s | optimized bound s | speedup | "
        "bottleneck (b→o) | useful (b→o) |",
        "|---|---|---|---|---|---|---|",
    ]
    for key in sorted(base):
        if key not in opt:
            continue
        b, o = base[key], opt[key]
        sp = b["step_lower_bound_s"] / o["step_lower_bound_s"]
        lines.append(
            f"| {key[0]} | {key[1]} | {b['step_lower_bound_s']:.2e} | "
            f"{o['step_lower_bound_s']:.2e} | {sp:.2f}x | "
            f"{b['bottleneck']}→{o['bottleneck']} | "
            f"{b['useful_ratio']:.3f}→{o['useful_ratio']:.3f} |"
        )
    return "\n".join(lines)
