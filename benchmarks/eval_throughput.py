"""Microbenchmark: host-sync filtered-ranking eval vs the device-batched
evaluation subsystem.

One validation eval boundary at FB15k-237 scale (E=14541, D=256, C=3,
~EVAL_TRIPLES eval triples per client; ``REPRO_BENCH_FAST=1`` shrinks to a
smoke size).  Two rows:

* ``eval.host_sync`` — the pre-PR boundary path: ``sync_clients`` pulls
  every padded entity table back to per-client host params, then each
  client ranks its eval split in 256-row jitted chunks with host-side
  filter masks re-shipped per chunk (``KGEClient.evaluate``).
* ``eval.device_batched`` — :class:`repro.core.evaluation.BatchedEvaluator`:
  one compiled program scores all clients' candidate sets at once (E-dim
  chunked scan, bit-packed filters applied with bitwise ops, ranks reduced
  on device); the host reads back a single ``(C, 3)`` scalar block.

Derived columns: eval triples/second (both legs counted) and host
dispatches per boundary (1 sync + one ``_rank_batch`` per 256-row chunk
per client, vs 1).  ``--json PATH`` writes a machine-readable record (CI
emits ``BENCH_eval.json`` alongside the other BENCH artifacts).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.fused_cycle import (  # noqa: E402
    BATCH, DIM, FAST, NEGATIVES, NUM_CLIENTS, NUM_GLOBAL, SUBSET, TRIPLES,
)
from repro.core.evaluation import BatchedEvaluator  # noqa: E402
from repro.core.protocol import build_comm_views  # noqa: E402
from repro.core.state import CycleEngine  # noqa: E402
from repro.data.partition import ClientData  # noqa: E402
from repro.federated.client import KGEClient  # noqa: E402
from repro.federated.metrics import (  # noqa: E402
    aggregate_eval_block,
    weighted_average,
)

EVAL_TRIPLES = 128 if FAST else 500  # per-client valid triples ranked


def _make_clients(rng):
    """FB15k-scale stand-in with a realistic eval split (the fused_cycle
    helper's 16-row splits would undersell the eval-path costs)."""
    num_rel = 12
    datas = []
    for c in range(NUM_CLIENTS):
        l2g = np.sort(
            rng.choice(NUM_GLOBAL, size=int(NUM_GLOBAL * SUBSET), replace=False)
        ).astype(np.int32)
        n_local = len(l2g)

        def triples(n):
            return np.stack(
                [
                    rng.integers(0, n_local, n),
                    rng.integers(0, num_rel, n),
                    rng.integers(0, n_local, n),
                ],
                axis=1,
            ).astype(np.int32)

        datas.append(
            ClientData(
                client_id=c,
                train=triples(TRIPLES),
                valid=triples(EVAL_TRIPLES),
                test=triples(EVAL_TRIPLES),
                local_to_global=l2g,
                num_relations=num_rel,
            )
        )
    clients = [
        KGEClient(d, method="transe", dim=DIM, batch_size=BATCH,
                  num_negatives=NEGATIVES, lr=1e-4, seed=0)
        for d in datas
    ]
    views = build_comm_views([d.local_to_global for d in datas], NUM_GLOBAL)
    return datas, clients, views


def run(out=print):
    rng = np.random.default_rng(0)
    datas, clients, views = _make_clients(rng)
    total_triples = sum(
        min(d.valid.shape[0], EVAL_TRIPLES) for d in datas
    )
    out(
        f"\n== eval boundary: {total_triples} triples x 2 legs, "
        f"E={NUM_GLOBAL} D={DIM} C={NUM_CLIENTS} =="
    )
    engine = CycleEngine(clients, views, NUM_GLOBAL, sparsity_p=0.4,
                         local_epochs=1)
    state = engine.init_state(clients, seed=0)
    evaluator = BatchedEvaluator(
        datas, method="transe", gamma=clients[0].gamma, e_max=engine.e_max,
        max_triples=EVAL_TRIPLES, splits=("valid",),
        known=[c._known for c in clients],
    )

    def host_boundary():
        engine.sync_clients(state, clients)
        return weighted_average(
            [c.evaluate("valid", EVAL_TRIPLES) for c in clients]
        )

    def device_boundary():
        return aggregate_eval_block(
            evaluator.evaluate(state.arrays.params, "valid")
        )

    # warm/compile both paths (also builds the host filter caches)
    val_host = host_boundary()
    val_dev = device_boundary()
    jax.block_until_ready(state.arrays.params["entity"])

    repeats = 5 if FAST else 3
    best = {"host_sync": float("inf"), "device_batched": float("inf")}
    for _ in range(repeats):
        for name, fn in (("host_sync", host_boundary),
                         ("device_batched", device_boundary)):
            t0 = time.perf_counter()
            fn()
            best[name] = min(best[name], time.perf_counter() - t0)

    chunks = sum(-(-min(d.valid.shape[0], EVAL_TRIPLES) // 256) for d in datas)
    disp = {"host_sync": 1 + chunks, "device_batched": 1}
    rows = []
    for name in ("host_sync", "device_batched"):
        s = best[name]
        rows.append((
            f"eval.{name}", s * 1e3, total_triples * 2 / s, disp[name]
        ))
    for name, ms, tps, d in rows:
        out(f"{name},{ms:.1f}ms,{tps:.0f} triples/s,{d} dispatches")
    return rows, val_host, val_dev


def check_claims(rows, val_host, val_dev):
    by = {r[0]: r for r in rows}
    speedup = by["eval.host_sync"][1] / by["eval.device_batched"][1]
    ok_speed = speedup >= 1.0
    ok_metric = abs(val_host["mrr"] - val_dev["mrr"]) < 1e-6
    return [
        f"[{'PASS' if ok_speed else 'WARN'}] device-batched eval {speedup:.2f}x "
        f"vs host-sync boundary (expect >= 1.0x; "
        f"{by['eval.host_sync'][3]} -> {by['eval.device_batched'][3]} host "
        f"dispatches per boundary)",
        f"[{'PASS' if ok_metric else 'FAIL'}] device MRR matches host oracle "
        f"({val_dev['mrr']:.6f} vs {val_host['mrr']:.6f}; integer ranks are "
        f"property-tested exactly equal in tests/test_evaluation.py)",
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, help="write a JSON record here")
    args = ap.parse_args()
    rows, val_host, val_dev = run()
    claims = check_claims(rows, val_host, val_dev)
    for c in claims:
        print(c)
    if args.json:
        rec = {
            "bench": "eval_throughput",
            "schema_version": 1,
            "fast": FAST,
            "config": {
                "num_global": NUM_GLOBAL, "dim": DIM, "clients": NUM_CLIENTS,
                "eval_triples_per_client": EVAL_TRIPLES,
            },
            "ms_per_boundary": {name: ms for name, ms, _, _ in rows},
            "triples_per_s": {name: tps for name, _, tps, _ in rows},
            "host_dispatches_per_boundary": {
                name: d for name, _, _, d in rows
            },
            "speedup_device_vs_host": rows[0][1] / rows[1][1],
            "mrr": {"host": val_host["mrr"], "device": val_dev["mrr"]},
            "claims": claims,
        }
        with open(args.json, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
