"""Beyond-paper: FedS+Q8 — entity-wise Top-K selection + int8 row payloads.

Probes the paper's core claim (§III-A: "universal reduction in embedding
precision ... impedes convergence").  FedS+Q8 reduces precision ONLY of the
selected rows on the wire (int8 + per-row scale), not of the training state:
if selection is the real mechanism, moderate wire quantization should be
nearly free — stacking another ~3x on top of the paper's ~2x.
"""
from benchmarks.common import fmt_row, make_config, run_cached


def run(methods=("transe", "rotate"), out=print):
    rows = []
    out("\n== FedS+Q8: int8 wire payloads on top of Top-K (R3) ==")
    out(fmt_row(["KGE", "setting", "MRR@CG", "params (vs FedEP)"]))
    for method in methods:
        fedep = run_cached(3, make_config("fedep", method))
        feds = run_cached(3, make_config("feds", method))
        q8 = run_cached(3, make_config("feds", method, quantize_upload=True))
        base = fedep.ledger.params_transmitted / fedep.ledger.rounds
        for name, res in (("fedep", fedep), ("feds", feds), ("feds+q8", q8)):
            ratio = (res.ledger.params_transmitted / res.ledger.rounds) / base
            rows.append({"kge": method, "setting": name,
                         "mrr": res.test_mrr_cg, "ratio": ratio})
            out(fmt_row([method, name, f"{res.test_mrr_cg:.4f}", f"{ratio:.4f}"]))
    return rows


def check_claims(rows):
    notes = []
    by = {(r["kge"], r["setting"]): r for r in rows}
    for kge in {r["kge"] for r in rows}:
        f, q = by[(kge, "feds")], by[(kge, "feds+q8")]
        acc_ok = q["mrr"] >= 0.93 * f["mrr"]
        comm_ok = q["ratio"] < f["ratio"] * 0.75
        notes.append(
            f"[{'PASS' if (acc_ok and comm_ok) else 'WARN'}] {kge}: FedS+Q8 MRR "
            f"{q['mrr']:.4f} vs FedS {f['mrr']:.4f} at {q['ratio']:.3f} vs "
            f"{f['ratio']:.3f} per-round ratio (selection, not precision, is "
            f"the mechanism)"
        )
    return notes
