"""Microbenchmark: one ISM period per program (superstep) vs one per round.

One full Intermittent-Synchronization period — ``s`` sparse FedS rounds
followed by 1 dense sync round, ``s+1`` rounds total — at FB15k-237 scale
(E=14541, D=256, C=3, local_epochs=3, s=4 by default; ``REPRO_BENCH_FAST=1``
shrinks to a smoke size).  Two rows:

* ``superstep.fused_per_cycle`` — the ``engine="fused"`` path: one compiled
  train+communicate program per round, i.e. per period ``s+1`` program
  dispatches plus ``s+1`` eager PRNG splits re-crossing the host loop.
* ``superstep.superstep`` — the :class:`repro.core.state.SuperstepEngine`
  path: the whole period ``lax.scan``-ned into ONE program, state + PRNG key
  + per-round download counts carried through the scan on device.

Derived columns: per-round speedup vs the fused path and host dispatches per
round (the superstep amortizes dispatch + ledger-accumulator plumbing over
``s+1`` rounds: 1 dispatch per period vs ``2(s+1)``).  ``--json PATH``
writes a machine-readable record (CI emits ``BENCH_superstep.json``
alongside ``BENCH_cycle.json``).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.fused_cycle import (  # noqa: E402
    BATCH, DIM, FAST, LOCAL_EPOCHS, NEGATIVES, NUM_CLIENTS, NUM_GLOBAL,
    SPARSITY, TRIPLES, _make_clients,
)
from repro.core.state import SuperstepEngine  # noqa: E402

SYNC_S = 4  # paper s: sparse rounds per sync round
PERIOD = SYNC_S + 1
KINDS = ("sparse",) * SYNC_S + ("sync",)


def _block(state):
    jax.block_until_ready(state.arrays.params["entity"])


def run(out=print):
    rng = np.random.default_rng(0)
    _, clients, views = _make_clients(rng)
    out(
        f"\n== superstep: {SYNC_S} sparse + 1 sync rounds "
        f"({LOCAL_EPOCHS} local epochs each), E={NUM_GLOBAL} D={DIM} "
        f"C={NUM_CLIENTS} T={TRIPLES} B={BATCH} N={NEGATIVES} p={SPARSITY} =="
    )
    engine = SuperstepEngine(
        clients, views, NUM_GLOBAL, sparsity_p=SPARSITY,
        local_epochs=LOCAL_EPOCHS,
    )
    repeats = 5 if FAST else 3
    downs = []

    def fused_period(state):
        for kind in KINDS:
            state, down, _ = engine.fused_cycle(state, sync=kind == "sync")
            if kind == "sparse":
                downs.append(down)  # device-resident until eval flush
        _block(state)
        return state

    def superstep_period(state):
        state, per_round, _ = engine.superstep(state, KINDS)
        downs.extend(d for k, d in per_round if k == "sparse")
        _block(state)
        return state

    # warm/compile both paths
    state = engine.init_state(clients, seed=0)
    state = fused_period(state)
    state = superstep_period(state)

    # interleave measurement blocks and take the per-path minimum — this
    # 2-core container is ~±5% noisy, which would otherwise swamp the gap
    best = {"fused": float("inf"), "superstep": float("inf")}
    for _ in range(repeats):
        for name, fn in (("fused", fused_period), ("superstep", superstep_period)):
            t0 = time.perf_counter()
            state = fn(state)
            best[name] = min(best[name], time.perf_counter() - t0)
    us_fused = best["fused"] / PERIOD * 1e6
    us_sstep = best["superstep"] / PERIOD * 1e6
    np.asarray(jax.numpy.stack(downs))  # eval-boundary flush (untimed)

    rows = [
        ("superstep.fused_per_cycle", us_fused, "1.00x"),
        ("superstep.superstep", us_sstep, f"{us_fused / us_sstep:.2f}x"),
    ]
    for name, us, derived in rows:
        out(f"{name},{us:.1f},{derived}")
    out(
        f"host dispatches/round: fused {2 * PERIOD}/{PERIOD}={2.0:.1f}, "
        f"superstep 1/{PERIOD}={1 / PERIOD:.1f}"
    )
    return rows


def check_claims(rows):
    by = {r[0]: r[1] for r in rows}
    speedup = by["superstep.fused_per_cycle"] / by["superstep.superstep"]
    ok = speedup >= 1.0
    saved = 2.0 - 1.0 / PERIOD  # host dispatches saved per round
    return [
        f"[{'PASS' if ok else 'WARN'}] superstep {speedup:.2f}x vs per-cycle "
        f"fused path (expect >=1.0x; {saved:.1f} fewer host dispatches per "
        f"round — one program per {PERIOD}-round period instead of "
        f"{2 * PERIOD})"
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, help="write a JSON record here")
    args = ap.parse_args()
    rows = run()
    claims = check_claims(rows)
    for c in claims:
        print(c)
    if args.json:
        rec = {
            "bench": "superstep",
            "schema_version": 1,
            "fast": FAST,
            "config": {
                "num_global": NUM_GLOBAL, "dim": DIM, "clients": NUM_CLIENTS,
                "local_epochs": LOCAL_EPOCHS, "triples": TRIPLES,
                "batch": BATCH, "negatives": NEGATIVES, "sparsity": SPARSITY,
                "sync_interval": SYNC_S,
            },
            "us_per_round": {name: us for name, us, _ in rows},
            "speedup_superstep_vs_fused": rows[0][1] / rows[1][1],
            "host_dispatches_per_round": {
                "fused_per_cycle": 2.0, "superstep": 1.0 / PERIOD,
            },
            "claims": claims,
        }
        with open(args.json, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
