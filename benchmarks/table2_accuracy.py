"""Table II: prediction accuracy (MRR / Hits@10) — Single vs FedEP vs FedS.

Paper claim: FedS converges to MRR within ~1% of FedEP (sometimes above it),
and both beat Single.
"""
from benchmarks.common import fmt_row, make_config, run_cached


def _overrides(method: str, nc: int) -> dict:
    # paper §IV-B: sparsity p=0.7 for ComplEx on R5, 0.4 everywhere else
    return {"sparsity_p": 0.7} if (method == "complex" and nc == 5) else {}


def run(methods=("transe", "rotate", "complex"), client_counts=(3, 5), out=print):
    rows = []
    out("\n== Table II: accuracy at convergence (synthetic R3/R5) ==")
    out(fmt_row(["KGE", "clients", "setting", "MRR", "Hits@10"]))
    for method in methods:
        for nc in client_counts:
            for proto in ("single", "fedep", "feds"):
                res = run_cached(nc, make_config(proto, method,
                                                 **_overrides(method, nc)))
                rows.append({
                    "kge": method, "clients": nc, "setting": proto,
                    "mrr": res.test_mrr_cg, "hits10": res.test_hits10_cg,
                    "val_mrr": res.val_mrr_cg,
                })
                out(fmt_row([method, nc, proto, f"{res.test_mrr_cg:.4f}",
                             f"{res.test_hits10_cg:.4f}"]))
    return rows


def check_claims(rows) -> list[str]:
    """Validate the paper's Table II claims on our runs."""
    notes = []
    by = {(r["kge"], r["clients"], r["setting"]): r for r in rows}
    for (kge, nc, setting), r in by.items():
        if setting != "feds":
            continue
        fedep = by[(kge, nc, "fedep")]
        ratio = r["mrr"] / max(fedep["mrr"], 1e-9)
        ok = ratio >= 0.95  # paper: >= ~0.99; we allow noise at tiny scale
        notes.append(
            f"[{'PASS' if ok else 'WARN'}] {kge}/R{nc}: FedS MRR = "
            f"{100*ratio:.1f}% of FedEP (paper: ~99-100%)"
        )
    return notes
