"""Table V: robustness of FedS across local-epoch counts."""
from benchmarks.common import comm_table_row, fmt_row, make_config, run_cached


def run(epochs=(2, 3, 4), out=print):
    rows = []
    out("\n== Table V: FedS vs FedEP across local epochs (TransE, R3) ==")
    out(fmt_row(["epochs", "setting", "MRR", "P@CG", "P@99", "P@98"]))
    for ep in epochs:
        fedep = run_cached(3, make_config("fedep", local_epochs=ep))
        feds = run_cached(3, make_config("feds", local_epochs=ep))
        r = comm_table_row(feds, fedep)
        rows.append({"epochs": ep, "mrr_fedep": fedep.test_mrr_cg,
                     "mrr_feds": feds.test_mrr_cg, **r})
        out(fmt_row([ep, "fedep", f"{fedep.test_mrr_cg:.4f}", "1.0", "1.0", "1.0"]))
        out(fmt_row([ep, "feds", f"{feds.test_mrr_cg:.4f}"]
                    + [f"{r[k]:.3f}" for k in ("P@CG", "P@99", "P@98")]))
    return rows


def check_claims(rows):
    return [
        f"[{'PASS' if r['mrr_feds'] >= 0.9 * r['mrr_fedep'] else 'WARN'}] "
        f"epochs={r['epochs']}: FedS MRR {r['mrr_feds']:.4f} ~ FedEP {r['mrr_fedep']:.4f}"
        for r in rows
    ]
