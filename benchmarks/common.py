"""Shared benchmark infrastructure.

The paper's experiments run on FB15k-237-R{10,5,3} with dim 256 for hundreds
of rounds on GPUs; this container is a single CPU core, so benchmarks run the
same *protocols* on the seeded synthetic KG at reduced scale (DESIGN.md §7).
The claims being validated are relative (FedS vs FedEP vs FedEPL ratios), not
absolute MRR.

``REPRO_BENCH_FAST=1`` shrinks rounds further for smoke runs.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import tempfile
import time

from repro.core.sync import comm_ratio_worst_case
from repro.data import generate_kg, partition_by_relation
from repro.federated.metrics import first_round_reaching
from repro.federated.simulation import FederatedConfig, FederatedResult, run_federated

FAST = os.environ.get("REPRO_BENCH_FAST", "0") == "1"

# CPU-budget experiment scale (paper values in comments).  REPRO_BENCH_DIM /
# REPRO_BENCH_ROUNDS move closer to paper scale (the compression baselines of
# Table I only show their capacity penalty at larger dims).
DIM = int(os.environ.get("REPRO_BENCH_DIM", "32"))  # paper: 256
ROUNDS = 12 if FAST else int(os.environ.get("REPRO_BENCH_ROUNDS", "40"))
LOCAL_EPOCHS = 3  # paper: 3
BATCH = 128  # paper: 512
NEG = 32  # paper: 256 negatives typical
LR = 1e-2  # paper: 1e-4 (scaled up for the tiny dim/graph)
SPARSITY = 0.4  # paper: 0.4 (0.7 for one ComplEx case)
SYNC_S = 4  # paper: 4
EVAL_EVERY = 4 if FAST else 5  # paper: 5
PATIENCE = 3  # paper: 3

_KG_CACHE = {}
_RESULT_CACHE: dict[tuple, FederatedResult] = {}


def dataset(num_clients: int):
    """Synthetic stand-in for FB15k-237-R{num_clients}."""
    if num_clients not in _KG_CACHE:
        kg = generate_kg(
            num_entities=250 if FAST else 400,
            num_relations=6 * num_clients,
            num_triples=2500 if FAST else 5000,
            seed=7,
        )
        _KG_CACHE[num_clients] = (kg, partition_by_relation(kg, num_clients, seed=0))
    return _KG_CACHE[num_clients]


def make_config(protocol: str, method: str = "transe", **overrides) -> FederatedConfig:
    base = dict(
        method=method, protocol=protocol, dim=DIM, rounds=ROUNDS,
        local_epochs=LOCAL_EPOCHS, batch_size=BATCH, num_negatives=NEG, lr=LR,
        sparsity_p=SPARSITY, sync_interval=SYNC_S, eval_every=EVAL_EVERY,
        patience=PATIENCE, max_eval_triples=80 if FAST else 150, seed=0,
    )
    base.update(overrides)
    return FederatedConfig(**base)


def run_cached(num_clients: int, cfg: FederatedConfig, verbose: bool = False):
    key = (num_clients, tuple(sorted(vars(cfg).items())))
    if key not in _RESULT_CACHE:
        kg, clients = dataset(num_clients)
        t0 = time.time()
        _RESULT_CACHE[key] = run_federated(clients, kg.num_entities, cfg, verbose)
        _RESULT_CACHE[key].wall_s = time.time() - t0  # type: ignore[attr-defined]
    return _RESULT_CACHE[key]


def divergence_round_means(jsonl_path: str) -> dict:
    """Mean shared-entity divergence by comm-round kind from a flight-recorder
    JSONL: ``{"sparse": mean of per-round mean div_mean, "sync": ...}``, with
    ``None`` for kinds that never happened (FedS/syn has no sync rounds)."""
    by_kind: dict[str, list[float]] = {"sparse": [], "sync": []}
    with open(jsonl_path) as f:
        for line in f:
            ev = json.loads(line)
            if ev.get("ev") == "round" and ev.get("kind") in by_kind:
                d = ev["div_mean"]
                by_kind[ev["kind"]].append(sum(d) / max(len(d), 1))
    return {
        k: (sum(v) / len(v) if v else None) for k, v in by_kind.items()
    }


_DIV_CACHE: dict[tuple, dict] = {}


def run_with_divergence(num_clients: int, cfg: FederatedConfig,
                        verbose: bool = False):
    """``run_cached`` with the flight recorder on: returns ``(result,
    divergence_round_means dict)`` from ONE run.  The recorder is
    observational (telemetry-off programs are bitwise identical), so the
    result is also seeded into the plain-config cache — suites that run the
    same config without telemetry reuse it instead of training again."""
    cfg = dataclasses.replace(cfg, telemetry="")
    key = (num_clients, tuple(sorted(vars(cfg).items())))
    if key not in _DIV_CACHE:
        fd, path = tempfile.mkstemp(suffix=".jsonl")
        os.close(fd)
        res = run_cached(
            num_clients, dataclasses.replace(cfg, telemetry=path), verbose
        )
        _DIV_CACHE[key] = divergence_round_means(path)
        os.unlink(path)
        _RESULT_CACHE[key] = res
    return _RESULT_CACHE[key], _DIV_CACHE[key]


def fedepl_dim(p: float = SPARSITY, s: int = SYNC_S, dim: int = DIM) -> int:
    """FedEPL embedding dim matching FedS's per-cycle budget (Appendix VI-C)."""
    return math.ceil(dim * comm_ratio_worst_case(p, s, dim))


# ------------------------------------------------------------------ metrics
def params_at_target(res: FederatedResult, target_mrr: float):
    """(round, cumulative params) at first attainment of target val MRR."""
    hist = [(r, m) for r, m, _ in res.eval_history]
    rd = first_round_reaching(hist, target_mrr)
    if rd is None:
        return None, None
    return rd, res.ledger.params_at_round(rd)


def comm_table_row(model: FederatedResult, baseline: FederatedResult) -> dict:
    """P@CG / P@99 / P@98 ratios of ``model`` vs ``baseline`` (FedEP)."""
    base_cg_params = baseline.ledger.params_at_round(baseline.best_round)
    model_cg_params = model.ledger.params_at_round(model.best_round)
    out = {"P@CG": model_cg_params / base_cg_params if base_cg_params else float("nan")}
    for frac, name in ((0.99, "P@99"), (0.98, "P@98")):
        target = frac * baseline.val_mrr_cg
        _, bp = params_at_target(baseline, target)
        _, mp = params_at_target(model, target)
        out[name] = (mp / bp) if (bp and mp) else float("nan")
    return out


def fmt_row(cols, widths=None):
    widths = widths or [18] * len(cols)
    return "  ".join(str(c)[:w].ljust(w) for c, w in zip(cols, widths))
