"""Benchmark driver: one module per paper table/figure + roofline + kernels.

Prints per-benchmark tables, a final ``name,us_per_call,derived`` CSV, and a
claim-validation summary (PASS/WARN per paper claim).  Full run takes tens of
minutes on this single CPU core; set REPRO_BENCH_FAST=1 for a quick pass, or
select suites with ``--only table3,roofline``.

``--aggregate [DIR]`` instead collects every ``--json`` record the CI
producers emitted into one schema-checked ``BENCH_summary.json``, and fails
loudly (non-zero exit) when a producer silently wrote nothing — the failure
mode where the "recorded perf trajectory" is quietly empty.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# Every benchmark that records a JSON trajectory in CI: artifact file ->
# (producer module, required "bench" tag).  tools/docs_lint.py checks each
# artifact is referenced in EXPERIMENTS.md; CI uploads them all.  Producers
# containing "/" are repo-relative script paths; bare names live under
# benchmarks/.
JSON_PRODUCERS = {
    "BENCH_cycle.json": ("fused_cycle", "fused_cycle"),
    "BENCH_superstep.json": ("superstep", "superstep"),
    "BENCH_codecs.json": ("codecs", "codecs"),
    "BENCH_scoring.json": ("scoring", "scoring"),
    "BENCH_eval.json": ("eval_throughput", "eval_throughput"),
    "BENCH_scale.json": ("scale_entities", "scale_entities"),
    "BENCH_churn.json": ("churn", "churn"),
    "BENCH_telemetry.json": ("telemetry_overhead", "telemetry_overhead"),
    "BENCH_trace.json": ("tools/trace_report", "trace_report"),
}

SCHEMA_VERSION = 1


def _producer_script(module: str) -> str:
    return f"{module}.py" if "/" in module else f"benchmarks/{module}.py"


def aggregate(bench_dir: str) -> int:
    """Merge all producer records into BENCH_summary.json; exit non-zero on
    a missing/empty/mistagged record so CI can't silently lose coverage."""
    records, errors = {}, []
    for fname, (module, tag) in sorted(JSON_PRODUCERS.items()):
        path = os.path.join(bench_dir, fname)
        if not os.path.exists(path):
            errors.append(f"{fname}: missing — {_producer_script(module)} "
                          f"produced no JSON record")
            continue
        try:
            with open(path) as f:
                rec = json.load(f)
        except ValueError as e:
            errors.append(f"{fname}: unparseable JSON ({e})")
            continue
        if not isinstance(rec, dict) or rec.get("bench") != tag:
            errors.append(f"{fname}: bad record — expected a dict with "
                          f'bench == "{tag}", got '
                          f"{rec.get('bench') if isinstance(rec, dict) else type(rec).__name__!r}")
            continue
        if rec.get("schema_version") != SCHEMA_VERSION:
            errors.append(
                f"{fname}: schema_version "
                f"{rec.get('schema_version')!r} != {SCHEMA_VERSION} — "
                f"{_producer_script(module)} emits a stale or missing "
                f"version; bump the producer, not the checker"
            )
            continue
        if not isinstance(rec.get("fast"), bool) or not rec.get("claims"):
            errors.append(f"{fname}: schema violation — every record needs "
                          f"a bool 'fast' and a non-empty 'claims' list")
            continue
        records[fname] = rec
    claims = [c for rec in records.values() for c in rec["claims"]]
    n_warn = sum("WARN" in c for c in claims)
    summary = {
        "records": records,
        "claims": claims,
        "claims_pass": len(claims) - n_warn,
        "claims_total": len(claims),
        "errors": errors,
    }
    out_path = os.path.join(bench_dir, "BENCH_summary.json")
    with open(out_path, "w") as f:
        json.dump(summary, f, indent=2)
    print(f"aggregated {len(records)}/{len(JSON_PRODUCERS)} records -> "
          f"{out_path} ({summary['claims_pass']}/{len(claims)} claims PASS)")
    for e in errors:
        print(f"  ERROR {e}", file=sys.stderr)
    return 1 if errors else 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: kernels,engine,cycle,sstep,codecs,"
                         "scoring,eval,scale,telemetry,table1,table2,table3,"
                         "table4,table5,table6,fig2,sweep,churn,q8,roofline")
    ap.add_argument("--aggregate", nargs="?", const=".", default=None,
                    metavar="DIR",
                    help="don't run suites; merge the BENCH_*.json records "
                         "in DIR (default .) into BENCH_summary.json and "
                         "fail if any producer wrote nothing")
    args = ap.parse_args()
    if args.aggregate is not None:
        sys.exit(aggregate(args.aggregate))
    only = set(args.only.split(",")) if args.only else None

    def want(name: str) -> bool:
        return only is None or name in only

    csv_rows: list[tuple[str, float, str]] = []
    claims: list[str] = []
    t_start = time.time()

    if want("kernels"):
        from benchmarks import kernels_micro

        csv_rows += [tuple(r) for r in kernels_micro.run()]

    if want("engine"):
        from benchmarks import engine_round

        rows = engine_round.run()
        csv_rows += [tuple(r) for r in rows]
        claims += engine_round.check_claims(rows)

    if want("cycle"):
        from benchmarks import fused_cycle

        rows = fused_cycle.run()
        csv_rows += [tuple(r) for r in rows]
        claims += fused_cycle.check_claims(rows)

    if want("sstep"):
        from benchmarks import superstep

        rows = superstep.run()
        csv_rows += [tuple(r) for r in rows]
        claims += superstep.check_claims(rows)

    if want("codecs"):
        from benchmarks import codecs

        rows, records = codecs.run()
        csv_rows += [tuple(r) for r in rows]
        claims += codecs.check_claims(records)

    if want("scoring"):
        from benchmarks import scoring

        rows, records = scoring.run()
        csv_rows += [tuple(r) for r in rows]
        claims += scoring.check_claims(records)

    if want("eval"):
        from benchmarks import eval_throughput

        rows, val_host, val_dev = eval_throughput.run()
        csv_rows += [(name, ms, f"{tps:.0f} triples/s")
                     for name, ms, tps, _ in rows]
        claims += eval_throughput.check_claims(rows, val_host, val_dev)

    if want("scale"):
        from benchmarks import scale_entities

        rows = scale_entities.run()
        csv_rows += [tuple(r) for r in rows]
        claims += scale_entities.check_claims(rows)

    if want("telemetry"):
        from benchmarks import telemetry_overhead

        rows, record = telemetry_overhead.run()
        csv_rows += [tuple(r) for r in rows]
        claims += telemetry_overhead.check_claims(record)

    suites = [
        ("table1", "table1_compression"),
        ("table2", "table2_accuracy"),
        ("table3", "table3_comm"),
        ("table4", "table4_fedepl"),
        ("table5", "table5_local_epochs"),
        ("table6", "table6_batch_size"),
        ("fig2", "fig2_sync_ablation"),
        ("sweep", "sweep_sparsity"),
        ("churn", "churn"),
        ("q8", "feds_q8"),
    ]
    for key, mod_name in suites:
        if not want(key):
            continue
        t0 = time.time()
        mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
        rows = mod.run()
        wall = time.time() - t0
        csv_rows.append((f"bench.{key}", wall * 1e6, f"{len(rows)}rows"))
        if hasattr(mod, "check_claims"):
            claims += [f"{key}: {n}" for n in mod.check_claims(rows)]

    if want("roofline"):
        from benchmarks import roofline

        path = "dryrun_results.jsonl"
        if os.path.exists(path):
            t0 = time.time()
            rows = roofline.run(path)
            csv_rows.append(("bench.roofline", (time.time() - t0) * 1e6,
                             f"{len(rows)}pairs"))
        else:
            print(f"[roofline] {path} not found — run "
                  f"`python -m repro.launch.dryrun --all --mesh both --out {path}` first")

    if claims:
        print("\n== paper-claim validation ==")
        for c in claims:
            print(" ", c)
        n_warn = sum("WARN" in c for c in claims)
        print(f"  ({len(claims) - n_warn}/{len(claims)} claims PASS)")

    print("\nname,us_per_call,derived")
    for name, us, derived in csv_rows:
        print(f"{name},{us:.1f},{derived}")
    print(f"\ntotal wall: {time.time() - t_start:.0f}s")


if __name__ == "__main__":
    main()
