"""Benchmark driver: one module per paper table/figure + roofline + kernels.

Prints per-benchmark tables, a final ``name,us_per_call,derived`` CSV, and a
claim-validation summary (PASS/WARN per paper claim).  Full run takes tens of
minutes on this single CPU core; set REPRO_BENCH_FAST=1 for a quick pass, or
select suites with ``--only table3,roofline``.

``--aggregate [DIR]`` instead collects every ``--json`` record the CI
producers emitted into one schema-checked ``BENCH_summary.json``, and fails
loudly (non-zero exit) when a producer silently wrote nothing — the failure
mode where the "recorded perf trajectory" is quietly empty.

The perf-regression sentinel rides the same records: ``--write-baseline``
flattens every producer record in ``--bench-dir`` to its numeric leaves
(timing-like paths skipped — wall clock moves with the host, not the code)
and snapshots them with tolerances into the ``--baseline`` file;
``--baseline BENCH_baseline.json --check`` re-flattens fresh records and
exits non-zero, naming the producer script, when a metric drifts out of
tolerance, vanishes, or its producer wrote nothing.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time

# Every benchmark that records a JSON trajectory in CI: artifact file ->
# (producer module, required "bench" tag).  tools/docs_lint.py checks each
# artifact is referenced in EXPERIMENTS.md; CI uploads them all.  Producers
# containing "/" are repo-relative script paths; bare names live under
# benchmarks/.
JSON_PRODUCERS = {
    "BENCH_cycle.json": ("fused_cycle", "fused_cycle"),
    "BENCH_superstep.json": ("superstep", "superstep"),
    "BENCH_codecs.json": ("codecs", "codecs"),
    "BENCH_scoring.json": ("scoring", "scoring"),
    "BENCH_eval.json": ("eval_throughput", "eval_throughput"),
    "BENCH_scale.json": ("scale_entities", "scale_entities"),
    "BENCH_churn.json": ("churn", "churn"),
    "BENCH_fig2.json": ("fig2_sync_ablation", "fig2_sync_ablation"),
    "BENCH_telemetry.json": ("telemetry_overhead", "telemetry_overhead"),
    "BENCH_trace.json": ("tools/trace_report", "trace_report"),
    "BENCH_health.json": ("tools/health_report", "health_report"),
}

SCHEMA_VERSION = 1


def _producer_script(module: str) -> str:
    return f"{module}.py" if "/" in module else f"benchmarks/{module}.py"


def aggregate(bench_dir: str) -> int:
    """Merge all producer records into BENCH_summary.json; exit non-zero on
    a missing/empty/mistagged record so CI can't silently lose coverage."""
    records, errors = {}, []
    for fname, (module, tag) in sorted(JSON_PRODUCERS.items()):
        path = os.path.join(bench_dir, fname)
        if not os.path.exists(path):
            errors.append(f"{fname}: missing — {_producer_script(module)} "
                          f"produced no JSON record")
            continue
        try:
            with open(path) as f:
                rec = json.load(f)
        except ValueError as e:
            errors.append(f"{fname}: unparseable JSON ({e})")
            continue
        if not isinstance(rec, dict) or rec.get("bench") != tag:
            errors.append(f"{fname}: bad record — expected a dict with "
                          f'bench == "{tag}", got '
                          f"{rec.get('bench') if isinstance(rec, dict) else type(rec).__name__!r}")
            continue
        if rec.get("schema_version") != SCHEMA_VERSION:
            errors.append(
                f"{fname}: schema_version "
                f"{rec.get('schema_version')!r} != {SCHEMA_VERSION} — "
                f"{_producer_script(module)} emits a stale or missing "
                f"version; bump the producer, not the checker"
            )
            continue
        if not isinstance(rec.get("fast"), bool) or not rec.get("claims"):
            errors.append(f"{fname}: schema violation — every record needs "
                          f"a bool 'fast' and a non-empty 'claims' list")
            continue
        records[fname] = rec
    claims = [c for rec in records.values() for c in rec["claims"]]
    n_warn = sum("WARN" in c for c in claims)
    summary = {
        "records": records,
        "claims": claims,
        "claims_pass": len(claims) - n_warn,
        "claims_total": len(claims),
        "errors": errors,
    }
    out_path = os.path.join(bench_dir, "BENCH_summary.json")
    with open(out_path, "w") as f:
        json.dump(summary, f, indent=2)
    print(f"aggregated {len(records)}/{len(JSON_PRODUCERS)} records -> "
          f"{out_path} ({summary['claims_pass']}/{len(claims)} claims PASS)")
    for e in errors:
        print(f"  ERROR {e}", file=sys.stderr)
    return 1 if errors else 0


# ---------------------------------------------------- perf-regression sentinel
# Numeric leaf paths containing any of these substrings are never compared:
# wall-clock / throughput numbers measure the host, not the code.  The list
# is snapshotted INTO the baseline file, so retuning it never needs a code
# change — edit the baseline and re-check.
BASELINE_SKIP = ("wall", "us_per", "time", "_ms", "per_sec", "source")
BASELINE_REL_TOL = 0.15  # generous: CI hosts differ in BLAS/arch
BASELINE_ABS_TOL = 0.02  # floor for near-zero metrics (divergence, MRR)


def _numeric_leaves(obj, prefix: str = "") -> dict:
    """Flatten a JSON record to ``{dotted.path: float}`` over its int/float
    leaves (bools are identity flags, strings are prose — neither is a
    metric)."""
    out: dict = {}
    if isinstance(obj, bool) or obj is None:
        return out
    if isinstance(obj, (int, float)):
        if math.isfinite(obj):
            out[prefix] = float(obj)
        return out
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(_numeric_leaves(v, f"{prefix}.{k}" if prefix else str(k)))
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            out.update(_numeric_leaves(v, f"{prefix}[{i}]"))
    return out


def _skipped(path: str, skip) -> bool:
    low = path.lower()
    return any(s in low for s in skip)


def write_baseline(bench_dir: str, baseline_path: str) -> int:
    """Snapshot every producer record's numeric leaves into the baseline."""
    metrics, missing, fast = {}, [], False
    for fname in sorted(JSON_PRODUCERS):
        path = os.path.join(bench_dir, fname)
        if not os.path.exists(path):
            missing.append(f"{fname} ({_producer_script(JSON_PRODUCERS[fname][0])})")
            continue
        with open(path) as f:
            rec = json.load(f)
        fast = fast or bool(rec.get("fast"))
        metrics[fname] = {
            p: v for p, v in sorted(_numeric_leaves(rec).items())
            if not _skipped(p, BASELINE_SKIP)
        }
    if not metrics:
        print(f"no producer records found in {bench_dir!r} — run the "
              f"benchmarks with --json first", file=sys.stderr)
        return 1
    baseline = {
        "bench": "baseline",
        "schema_version": SCHEMA_VERSION,
        "fast": fast,
        "rel_tol": BASELINE_REL_TOL,
        "abs_tol": BASELINE_ABS_TOL,
        "skip": list(BASELINE_SKIP),
        "metrics": metrics,
    }
    with open(baseline_path, "w") as f:
        json.dump(baseline, f, indent=2)
        f.write("\n")
    n = sum(len(v) for v in metrics.values())
    print(f"baseline: {n} metric(s) from {len(metrics)} record(s) -> "
          f"{baseline_path}")
    for m in missing:
        print(f"  (no record for {m} — not covered by this baseline)")
    return 0


def check_baseline(bench_dir: str, baseline_path: str) -> int:
    """Compare fresh producer records against the committed baseline; every
    error names the producer script so the regression has an owner."""
    try:
        with open(baseline_path) as f:
            base = json.load(f)
    except (OSError, ValueError) as e:
        print(f"cannot read baseline {baseline_path}: {e}", file=sys.stderr)
        return 1
    rel_tol = base.get("rel_tol", BASELINE_REL_TOL)
    abs_tol = base.get("abs_tol", BASELINE_ABS_TOL)
    skip = tuple(base.get("skip", BASELINE_SKIP))
    errors: list[str] = []
    compared = 0
    for fname, wants in sorted(base.get("metrics", {}).items()):
        producer = (_producer_script(JSON_PRODUCERS[fname][0])
                    if fname in JSON_PRODUCERS else fname)
        path = os.path.join(bench_dir, fname)
        if not os.path.exists(path):
            errors.append(f"{fname}: missing — {producer} produced no "
                          f"record to compare")
            continue
        with open(path) as f:
            rec = json.load(f)
        if bool(rec.get("fast")) != bool(base.get("fast")):
            errors.append(
                f"{fname}: fast={rec.get('fast')} but the baseline was "
                f"recorded with fast={base.get('fast')} — regenerate with "
                f"--write-baseline under the same REPRO_BENCH_FAST"
            )
            continue
        got = _numeric_leaves(rec)
        for p, want in sorted(wants.items()):
            if _skipped(p, skip):
                continue
            if p not in got:
                errors.append(f"{fname}: metric {p} vanished from the "
                              f"record — check {producer}")
                continue
            compared += 1
            tol = max(rel_tol * abs(want), abs_tol)
            if abs(got[p] - want) > tol:
                errors.append(
                    f"{fname}: {p} = {got[p]:.6g}, baseline {want:.6g} "
                    f"(tolerance ±{tol:.4g}) — check {producer}"
                )
    print(f"perf sentinel: {compared} metric(s) vs {baseline_path}, "
          f"{len(errors)} problem(s)")
    for e in errors:
        print(f"  REGRESSION {e}", file=sys.stderr)
    return 1 if errors else 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: kernels,engine,cycle,sstep,codecs,"
                         "scoring,eval,scale,telemetry,table1,table2,table3,"
                         "table4,table5,table6,fig2,sweep,churn,q8,roofline")
    ap.add_argument("--aggregate", nargs="?", const=".", default=None,
                    metavar="DIR",
                    help="don't run suites; merge the BENCH_*.json records "
                         "in DIR (default .) into BENCH_summary.json and "
                         "fail if any producer wrote nothing")
    ap.add_argument("--baseline", default="BENCH_baseline.json",
                    metavar="PATH",
                    help="perf-sentinel baseline file (read by --check, "
                         "written by --write-baseline)")
    ap.add_argument("--check", action="store_true",
                    help="don't run suites; compare the producer records in "
                         "--bench-dir against --baseline and exit non-zero "
                         "on any out-of-tolerance metric (producer named)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="don't run suites; snapshot the producer records "
                         "in --bench-dir into --baseline")
    ap.add_argument("--bench-dir", default=".", metavar="DIR",
                    help="where the BENCH_*.json producer records live "
                         "(default .)")
    args = ap.parse_args()
    if args.write_baseline:
        sys.exit(write_baseline(args.bench_dir, args.baseline))
    if args.check:
        sys.exit(check_baseline(args.bench_dir, args.baseline))
    if args.aggregate is not None:
        sys.exit(aggregate(args.aggregate))
    only = set(args.only.split(",")) if args.only else None

    def want(name: str) -> bool:
        return only is None or name in only

    csv_rows: list[tuple[str, float, str]] = []
    claims: list[str] = []
    t_start = time.time()

    if want("kernels"):
        from benchmarks import kernels_micro

        csv_rows += [tuple(r) for r in kernels_micro.run()]

    if want("engine"):
        from benchmarks import engine_round

        rows = engine_round.run()
        csv_rows += [tuple(r) for r in rows]
        claims += engine_round.check_claims(rows)

    if want("cycle"):
        from benchmarks import fused_cycle

        rows = fused_cycle.run()
        csv_rows += [tuple(r) for r in rows]
        claims += fused_cycle.check_claims(rows)

    if want("sstep"):
        from benchmarks import superstep

        rows = superstep.run()
        csv_rows += [tuple(r) for r in rows]
        claims += superstep.check_claims(rows)

    if want("codecs"):
        from benchmarks import codecs

        rows, records = codecs.run()
        csv_rows += [tuple(r) for r in rows]
        claims += codecs.check_claims(records)

    if want("scoring"):
        from benchmarks import scoring

        rows, records = scoring.run()
        csv_rows += [tuple(r) for r in rows]
        claims += scoring.check_claims(records)

    if want("eval"):
        from benchmarks import eval_throughput

        rows, val_host, val_dev = eval_throughput.run()
        csv_rows += [(name, ms, f"{tps:.0f} triples/s")
                     for name, ms, tps, _ in rows]
        claims += eval_throughput.check_claims(rows, val_host, val_dev)

    if want("scale"):
        from benchmarks import scale_entities

        rows = scale_entities.run()
        csv_rows += [tuple(r) for r in rows]
        claims += scale_entities.check_claims(rows)

    if want("telemetry"):
        from benchmarks import telemetry_overhead

        rows, record = telemetry_overhead.run()
        csv_rows += [tuple(r) for r in rows]
        claims += telemetry_overhead.check_claims(record)

    suites = [
        ("table1", "table1_compression"),
        ("table2", "table2_accuracy"),
        ("table3", "table3_comm"),
        ("table4", "table4_fedepl"),
        ("table5", "table5_local_epochs"),
        ("table6", "table6_batch_size"),
        ("fig2", "fig2_sync_ablation"),
        ("sweep", "sweep_sparsity"),
        ("churn", "churn"),
        ("q8", "feds_q8"),
    ]
    for key, mod_name in suites:
        if not want(key):
            continue
        t0 = time.time()
        mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
        rows = mod.run()
        wall = time.time() - t0
        csv_rows.append((f"bench.{key}", wall * 1e6, f"{len(rows)}rows"))
        if hasattr(mod, "check_claims"):
            claims += [f"{key}: {n}" for n in mod.check_claims(rows)]

    if want("roofline"):
        from benchmarks import roofline

        path = "dryrun_results.jsonl"
        if os.path.exists(path):
            t0 = time.time()
            rows = roofline.run(path)
            csv_rows.append(("bench.roofline", (time.time() - t0) * 1e6,
                             f"{len(rows)}pairs"))
        else:
            print(f"[roofline] {path} not found — run "
                  f"`python -m repro.launch.dryrun --all --mesh both --out {path}` first")

    if claims:
        print("\n== paper-claim validation ==")
        for c in claims:
            print(" ", c)
        n_warn = sum("WARN" in c for c in claims)
        print(f"  ({len(claims) - n_warn}/{len(claims)} claims PASS)")

    print("\nname,us_per_call,derived")
    for name, us, derived in csv_rows:
        print(f"{name},{us:.1f},{derived}")
    print(f"\ntotal wall: {time.time() - t_start:.0f}s")


if __name__ == "__main__":
    main()
