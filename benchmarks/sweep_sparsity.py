"""Beyond-paper ablation: sparsity ratio p and sync interval s sweeps.

The paper fixes p=0.4 (0.7 for one ComplEx case) and s=4.  This sweep maps
the comm/accuracy frontier and validates Eq. 5 against the LIVE ledger at
every point (the worst-case formula must upper-bound the measured ratio and
be tight when every client finds K downstream candidates).
"""
from benchmarks.common import SYNC_S, DIM, fmt_row, make_config, run_cached
from repro.core.sync import comm_ratio_worst_case


def run(ps=(0.2, 0.4, 0.6, 0.8), ss=(2, 4, 8), out=print):
    rows = []
    fedep = run_cached(3, make_config("fedep"))
    base_per_round = fedep.ledger.params_transmitted / fedep.ledger.rounds

    out("\n== Sparsity-ratio sweep (TransE, R3, s=4) ==")
    out(fmt_row(["p", "MRR@CG", "measured ratio", "Eq.5 bound", "tight?"]))
    for p in ps:
        res = run_cached(3, make_config("feds", sparsity_p=p))
        measured = (res.ledger.params_transmitted / res.ledger.rounds) / base_per_round
        bound = comm_ratio_worst_case(p, SYNC_S, DIM)
        rows.append({"kind": "p", "value": p, "mrr": res.test_mrr_cg,
                     "measured": measured, "bound": bound})
        out(fmt_row([p, f"{res.test_mrr_cg:.4f}", f"{measured:.4f}",
                     f"{bound:.4f}", "Y" if measured <= bound * 1.02 else "N"]))

    out("\n== Sync-interval sweep (TransE, R3, p=0.4) ==")
    out(fmt_row(["s", "MRR@CG", "measured ratio", "Eq.5 bound", "tight?"]))
    for s in ss:
        res = run_cached(3, make_config("feds", sync_interval=s))
        measured = (res.ledger.params_transmitted / res.ledger.rounds) / base_per_round
        bound = comm_ratio_worst_case(0.4, s, DIM)
        rows.append({"kind": "s", "value": s, "mrr": res.test_mrr_cg,
                     "measured": measured, "bound": bound})
        out(fmt_row([s, f"{res.test_mrr_cg:.4f}", f"{measured:.4f}",
                     f"{bound:.4f}", "Y" if measured <= bound * 1.02 else "N"]))
    return rows


def check_claims(rows):
    notes = []
    for r in rows:
        ok = r["measured"] <= r["bound"] * 1.02
        notes.append(
            f"[{'PASS' if ok else 'WARN'}] {r['kind']}={r['value']}: measured "
            f"per-round ratio {r['measured']:.3f} <= Eq.5 bound {r['bound']:.3f}"
        )
    return notes
