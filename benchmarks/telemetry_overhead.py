"""Flight-recorder overhead: telemetry-on vs telemetry-off wall clock.

The telemetry design claims near-zero cost on both sides of the switch:

* **off** — the engines compile the exact pre-telemetry programs (the
  ``tel=None`` carry contributes zero pytree leaves), so off IS the
  baseline, not merely close to it;
* **on** — records ride the existing scan carries and drain with the
  deferred ledger flush (no extra dispatches), so the *per-round* cost
  should stay within a few percent (gated at <2%; the claim is PASS/WARN
  because timing on a shared CPU core is noisy).

A fresh ``run_federated`` call reconstructs its engines and recompiles
their programs, and the two variants compile *different* program families
— so a single-run wall-clock delta mostly measures a one-time compile
difference, not the recorder.  The gate therefore measures the marginal
per-round slope: each variant is timed at two round counts and the
compile/setup constant cancels in the difference.  The isolated fused
cycle (engine-level, no sink) times identically with telemetry on or off.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import FAST, ROUNDS, dataset, make_config
from repro.federated.simulation import run_federated

NUM_CLIENTS = 3
OVERHEAD_CLAIM = 0.02  # <2% wall-clock delta


def _timed_run(clients, num_entities, cfg) -> float:
    t0 = time.time()
    run_federated(clients, num_entities, cfg)
    return time.time() - t0


def run():
    kg, clients = dataset(NUM_CLIENTS)
    r_short, r_long = ROUNDS, 3 * ROUNDS
    # patience off: the slope needs both round counts to actually run
    # (early stopping would silently shrink the long run's denominator)
    cfg_off = make_config("feds", engine="fused", patience=10 ** 9)
    tmp = tempfile.NamedTemporaryFile(
        suffix=".jsonl", delete=False
    )
    tmp.close()
    cfg_on = dataclasses.replace(cfg_off, telemetry=tmp.name)
    try:
        # warmup: compile both variants (on adds a carry, so its programs
        # differ) before any timed run
        _timed_run(clients, kg.num_entities, cfg_off)
        _timed_run(clients, kg.num_entities, cfg_on)
        times = {}
        for name, cfg in (("off", cfg_off), ("on", cfg_on)):
            for r in (r_short, r_long):
                # min-of-2 per cell: the slope divides a difference of
                # wall times, so one scheduler hiccup would swing it
                times[name, r] = min(
                    _timed_run(
                        clients, kg.num_entities,
                        dataclasses.replace(cfg, rounds=r),
                    )
                    for _ in range(2)
                )
        events = sum(1 for _ in open(tmp.name))
    finally:
        os.unlink(tmp.name)
    # marginal per-round cost: the engine-reconstruction/compile constant
    # cancels in the long-minus-short difference
    dr = r_long - r_short
    off_round = (times["off", r_long] - times["off", r_short]) / dr
    on_round = (times["on", r_long] - times["on", r_short]) / dr
    overhead = on_round / off_round - 1.0
    rows = [
        ("telemetry.off", off_round * 1e6, f"{r_long}rounds"),
        ("telemetry.on", on_round * 1e6, f"{events}events"),
    ]
    record = {
        "off_round_s": off_round, "on_round_s": on_round,
        "off_s": times["off", r_long], "on_s": times["on", r_long],
        "overhead": overhead, "events": events, "rounds": r_long,
    }
    return rows, record


def check_claims(record) -> list[str]:
    ok = record["overhead"] < OVERHEAD_CLAIM
    return [
        f"[{'PASS' if ok else 'WARN'}] telemetry: flight recorder costs "
        f"{100 * record['overhead']:+.1f}% marginal wall clock per round "
        f"(claim < {100 * OVERHEAD_CLAIM:.0f}%; "
        f"{record['events']} events over {record['rounds']} rounds)"
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, help="write a JSON record here")
    args = ap.parse_args()
    rows, record = run()
    claims = check_claims(record)
    for name, us, derived in rows:
        print(f"{name}: {us / 1e3:.1f} ms/round ({derived})")
    for c in claims:
        print(c)
    if args.json:
        rec = {
            "bench": "telemetry_overhead",
            "schema_version": 1,
            "fast": FAST,
            "config": {"clients": NUM_CLIENTS, "rounds": ROUNDS},
            "result": record,
            "claims": claims,
        }
        with open(args.json, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
